"""End-to-end sparse-training behaviour (paper-level claims at smoke scale)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import SparseConfig
from repro.core import apply_masks, mask_stats, tree_paths
from repro.data import batch_for
from repro.optim import LRSchedule, OptConfig
from repro.training import (
    init_train_state,
    make_algo,
    make_rigl_step,
    make_train_step,
    snip_init,
)


def _run(method, steps=150, sparsity=0.8, seed=0, arch="h2o-danube-1.8b"):
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(
        cfg,
        sparse=SparseConfig(sparsity=sparsity, method=method, delta_t=20, alpha=0.3),
    )
    opt = OptConfig(kind="adam", weight_decay=0.0, grad_clip=1.0)
    lr = LRSchedule(base_lr=3e-3, warmup_steps=20, total_steps=steps)
    algo = make_algo(cfg, steps)
    state, _, _ = init_train_state(jax.random.PRNGKey(seed), cfg, opt)
    if method == "snip":
        state = snip_init(state, cfg, batch_for(cfg, 0, 8, 64, learnable=True))
    train = jax.jit(make_train_step(cfg, opt, lr))
    rigl = jax.jit(make_rigl_step(cfg, algo, lr))
    losses = []
    for t in range(steps):
        b = batch_for(cfg, t, 8, 64, learnable=True)
        if (
            method in ("rigl", "set", "snfs")
            and t > 0
            and t % 20 == 0
            and t < algo.schedule.t_end
        ):
            state, m = rigl(state, b)
        else:
            state, m = train(state, b)
        losses.append(float(m["loss"]))
    return cfg, state, losses


@pytest.mark.parametrize("method", ["rigl", "set", "static", "snfs", "snip"])
def test_methods_learn_and_preserve_nnz(method):
    cfg, state, losses = _run(method)
    assert losses[-1] < losses[0] * 0.7, f"{method} failed to learn"
    st = mask_stats(state["masks"])
    assert abs(st["sparsity"] - 0.8) < 0.02


def test_masked_weights_stay_zero_through_training():
    cfg, state, _ = _run("rigl", steps=60)
    w_eff = apply_masks(state["params"], state["masks"])
    for name, m in tree_paths(state["masks"]).items():
        if m is None:
            continue
        w = tree_paths(w_eff)[name]
        assert float(jnp.max(jnp.abs(jnp.where(m, 0.0, w)))) == 0.0


def test_topology_actually_changes():
    """RigL must rewire: initial and final masks differ substantially."""
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    cfg = dataclasses.replace(
        cfg, sparse=SparseConfig(sparsity=0.8, method="rigl", delta_t=10, alpha=0.3)
    )
    opt = OptConfig(kind="adam", grad_clip=1.0, weight_decay=0.0)
    lr = LRSchedule(base_lr=3e-3, warmup_steps=10, total_steps=100)
    algo = make_algo(cfg, 100)
    state, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    m0 = jax.tree_util.tree_map(
        lambda m: None if m is None else m.copy(),
        state["masks"],
        is_leaf=lambda x: x is None,
    )
    train = jax.jit(make_train_step(cfg, opt, lr))
    rigl = jax.jit(make_rigl_step(cfg, algo, lr))
    for t in range(60):
        b = batch_for(cfg, t, 8, 64, learnable=True)
        state, _ = (rigl if (t > 0 and t % 10 == 0) else train)(state, b)
    changed = 0
    total = 0
    for a, b_ in zip(
        jax.tree_util.tree_leaves(m0), jax.tree_util.tree_leaves(state["masks"])
    ):
        changed += int(jnp.sum(a != b_))
        total += a.size
    assert changed / total > 0.01, "masks never changed"


def test_dense_gradient_equals_masked_grad_composition():
    """One backward yields both: g_sparse == g_dense * mask (paper §3)."""
    from repro.models import init_lm, lm_loss

    cfg = get_config("h2o-danube-1.8b", smoke=True)
    cfg = dataclasses.replace(
        cfg, dtype="float32", sparse=SparseConfig(sparsity=0.5)
    )
    state, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, OptConfig())
    batch = batch_for(cfg, 0, 4, 32, learnable=True)
    w_eff = apply_masks(state["params"], state["masks"])
    g_dense = jax.grad(lambda p: lm_loss(p, cfg, batch))(w_eff)

    # gradient w.r.t. stored params (chain rule applies mask)
    def loss_via_params(p):
        return lm_loss(apply_masks(p, state["masks"]), cfg, batch)

    g_params = jax.grad(loss_via_params)(state["params"])
    flat_gd = tree_paths(g_dense)
    flat_gp = tree_paths(g_params)
    for name, m in tree_paths(state["masks"]).items():
        if m is None:
            continue
        expected = flat_gd[name] * m
        np.testing.assert_allclose(
            np.asarray(flat_gp[name]), np.asarray(expected), atol=1e-6
        )
        # dense grad is nonzero somewhere OUTSIDE the mask (it sees everything)
        outside = np.asarray(jnp.where(m, 0.0, flat_gd[name]))
        assert np.abs(outside).max() > 0


def test_snfs_tracks_dense_momentum():
    cfg, state, _ = _run("snfs", steps=30)
    assert "dense_mom" in state
    mom_nonzero = any(
        float(jnp.max(jnp.abs(x))) > 0
        for x in jax.tree_util.tree_leaves(state["dense_mom"])
    )
    assert mom_nonzero


# ---------------------------------------------------------------------------
# Pallas kernel-dispatch mode (cfg.sparse.kernel != 'dense')
# ---------------------------------------------------------------------------

def _kernel_cfg(kernel, arch="h2o-danube-1.8b", block=16, sparsity=0.8, method="rigl"):
    cfg = get_config(arch, smoke=True)
    sp = dict(sparsity=sparsity, method=method, delta_t=10, alpha=0.3, kernel=kernel)
    if kernel == "block_sparse":
        sp["block_shape"] = (block, block)
        sp["kernel_block"] = (128, block, block)
    else:
        sp["kernel_block"] = (128, 32, 32)
    return dataclasses.replace(cfg, sparse=SparseConfig(**sp))


@pytest.mark.parametrize("method", ["rigl", "snfs", "topkast"])
def test_block_sparse_kernel_trains_end_to_end(monkeypatch, method):
    """50 steps through make_train_step with kernel='block_sparse' for every
    gradient-guided method: loss must decrease, nnz must be preserved, masks
    must stay block-aligned, and apply_masks must NEVER run on the dispatched
    hot path (the masked weight copy is never materialized).  snfs/topkast
    here is itself a regression test — both used to be rejected under kernel
    dispatch; the superset PackState channel (core/pack.py::pack_entry) lifted
    that restriction."""
    import repro.models.model as model_mod
    import repro.training.steps as steps_mod

    cfg = _kernel_cfg("block_sparse", method=method)
    opt = OptConfig(kind="adam", weight_decay=0.0, grad_clip=1.0)
    steps = 50
    lr = LRSchedule(base_lr=3e-3, warmup_steps=10, total_steps=steps)
    algo = make_algo(cfg, steps)
    state, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    nnz0 = mask_stats(state["masks"])["nnz"]

    calls = {"n": 0}
    real_apply = steps_mod.apply_masks

    def spy(params, masks):
        calls["n"] += 1
        return real_apply(params, masks)

    monkeypatch.setattr(steps_mod, "apply_masks", spy)
    monkeypatch.setattr(model_mod, "apply_masks", spy)

    from repro.training import refresh_pack

    assert "pack" in state, "block_sparse state must carry PackState"
    train = jax.jit(make_train_step(cfg, opt, lr))
    rigl = jax.jit(make_rigl_step(cfg, algo, lr))
    losses = []
    for t in range(steps):
        b = batch_for(cfg, t, 4, 32, learnable=True)
        if t > 0 and t % 10 == 0 and t < algo.schedule.t_end:
            state, m = rigl(state, b)  # dense backward, amortized — MAY apply
            # driver contract: every topology update re-packs the tight grids
            state = refresh_pack(state, cfg)
        else:
            n_before = calls["n"]
            state, m = train(state, b)
            assert calls["n"] == n_before, (
                "train_step materialized w*m despite kernel dispatch"
            )
            assert int(m["pack_stale"]) == 0, (
                "PackState out of sync with masks (missing refresh_pack?)"
            )
        losses.append(float(m["loss"]))

    assert losses[-1] < losses[0] * 0.7, "block_sparse kernel failed to learn"
    st = mask_stats(state["masks"])
    assert st["nnz"] == nnz0, "topology updates must preserve nnz"
    # every mask still block-aligned (executable by the block kernel)
    for name, mk in tree_paths(state["masks"]).items():
        if mk is None:
            continue
        K, N = mk.shape
        per = np.asarray(mk).reshape(K // 16, 16, N // 16, 16).sum(axis=(1, 3))
        assert set(np.unique(per)) <= {0, 16 * 16}, name


def test_masked_kernel_grads_match_legacy_path():
    """Dispatched loss/grads (raw params + masks) == legacy apply_masks path."""
    from repro.models import lm_loss

    cfg = dataclasses.replace(_kernel_cfg("masked"), dtype="float32")
    state, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, OptConfig())
    batch = batch_for(cfg, 0, 4, 32, learnable=True)

    l_disp, g_disp = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, batch, masks=state["masks"])
    )(state["params"])
    l_leg, g_leg = jax.value_and_grad(
        lambda p: lm_loss(apply_masks(p, state["masks"]), cfg, batch)
    )(state["params"])
    np.testing.assert_allclose(float(l_disp), float(l_leg), rtol=1e-4)
    fd, fl = tree_paths(g_disp), tree_paths(g_leg)
    for name in fd:
        np.testing.assert_allclose(
            np.asarray(fd[name]), np.asarray(fl[name]),
            rtol=1e-3, atol=2e-4, err_msg=name,
        )


def test_snfs_no_longer_rejected_under_kernel_dispatch():
    """Regression: make_train_step used to raise ValueError('snfs ... dense')
    for any non-dense kernel — SNFS grow scores needed a dense gradient the
    dispatched path never materialized.  The backward-superset channel
    (training/steps.py::needs_bwd_masks) now feeds grow scores from the
    superset gradient, so construction must succeed for every kernel."""
    for kernel in ("masked", "block_sparse"):
        cfg = _kernel_cfg(kernel, method="snfs")
        step = make_train_step(cfg, OptConfig(), LRSchedule(total_steps=10))
        assert callable(step)


def test_block_sparse_requires_matching_block_shape():
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    cfg = dataclasses.replace(
        cfg,
        sparse=SparseConfig(kernel="block_sparse", block_shape=None),
    )
    with pytest.raises(ValueError, match="block-aligned"):
        make_train_step(cfg, OptConfig(), LRSchedule(total_steps=10))
